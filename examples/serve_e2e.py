"""End-to-end serving driver: ragged continuous batching with pack-once DSBP
int8 weights (the macro's offline weight path).

Four engines over the same checkpoint serve the SAME ragged prompt mix:
  float    — no quantization (baseline numerics)
  per-call — DSBP preset, raw weights re-quantized inside every matmul
  packed   — DSBP preset, weights packed ONCE at Engine init (the paper's
             offline/on-the-fly split); must match per-call token-for-token
  spec     — the packed engine serving speculatively (DESIGN.md §10):
             draft --spec-k tokens per pool step with the MSB-slice view of
             the same containers, verify in one batched target forward;
             must match the packed engine token-for-token

Each request additionally must match its own batch-size-1 generation
(length-aware batching: ragged prompts cannot perturb each other).

A fifth scenario serves a SHARED-SYSTEM-PROMPT workload through the paged
KV engine (DESIGN.md §12): 2x --batch requests sharing one system prompt
run concurrently on the KV HBM budget of --batch dense slots — prefix
blocks are physically shared (refcount > 1, copy-on-write on divergence)
and the token streams still match the dense packed engine exactly.

A sixth scenario serves the ragged mix with a DSBP-QUANTIZED KV CACHE
(DESIGN.md §14): K/V quantize at cache-write time into int8 aligned
mantissas + pow2 group scales (``kv_quant='kv8'``), attention consumes
the packed blocks without materializing a float cache, and the measured
``kv_bytes_per_token`` must come in >= 3x below the float cache.  The
exactness contract is dense-kv8 == paged-kv8 token-for-token (same
numerics, two schedulers); agreement with the float cache is reported
like the float-vs-DSBP weight agreement above (kv8 rounding, like
weight rounding, may legitimately move argmax on random smoke weights —
the pinned-seed parity suite lives in tests/test_kvq.py and the CI
gate).

A seventh scenario stress-tests the robustness layer (DESIGN.md §13): the
same mix plus a long low-priority request on an OVER-SUBSCRIBED block
pool, under a seeded fault plan (allocator refusals, COW contention, a
NaN injection, a mid-stream cancel) with ``numeric_guard='quarantine'``.
The run must finish with a lifecycle status for every request, zero lost
requests, preempted lanes resumed bit-exactly, and the block-conservation
invariants green after every scheduler iteration.

An eighth scenario replays that fault mix with OBSERVABILITY on
(DESIGN.md §15, ``observe=True``): every request must close a complete
span tree whose terminal status matches ``request_status``, the trace
recorder must drop zero events, and a forced NaN injection must surface
as non-empty quantization-health guard telemetry.

  PYTHONPATH=src python examples/serve_e2e.py --new-tokens 16
"""
import argparse
import time

import numpy as np
import jax

from repro.configs import smoke_config
from repro.models import model as M
from repro.serve import faults as FA
from repro.serve.engine import Engine, Request, ServeConfig


def _timed_serve(eng, prompts, n_new):
    eng.serve(prompts, max_new_tokens=2)  # warm every admission prefill shape
    t0 = time.monotonic()
    out = eng.serve(prompts, max_new_tokens=n_new)
    # wall incl. admission prefills + decode-phase tok/s (prefill excluded —
    # speculation changes the decode policy, not the prompt cost)
    return out, time.monotonic() - t0, eng.last_stats["decode_tps"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--preset", default="precise")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--spec-k", type=int, default=3)
    ap.add_argument("--spec-draft-bits", type=int, default=6)
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(remat=False, d_model=256, d_ff=512,
                                          vocab_size=1024)
    cfg_q = cfg.replace(quant=args.preset)
    params = M.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(0)
    # ragged mix: 2 requests per slot, lengths in [L/2, L]
    lens = rng.integers(args.prompt_len // 2, args.prompt_len + 1,
                        2 * args.batch)
    prompts = [rng.integers(0, cfg.vocab_size, (int(l),)) for l in lens]
    scfg = ServeConfig(max_len=128, batch_size=args.batch)

    eng_f = Engine(params, cfg, scfg)
    eng_percall = Engine(params, cfg_q, ServeConfig(
        max_len=128, batch_size=args.batch, pack=False))
    eng_packed = Engine(params, cfg_q, scfg)
    # same packed tree, speculative scheduler: zero extra weight HBM
    eng_spec = Engine(eng_packed.params, cfg_q, ServeConfig(
        max_len=128, batch_size=args.batch, spec_k=args.spec_k,
        spec_draft_bits=args.spec_draft_bits))

    rep = eng_packed.pack_report
    print(f"weights: {rep['raw_nbytes']/1e6:.1f} MB f32 -> "
          f"{rep['packed_nbytes']/1e6:.1f} MB packed "
          f"({rep['raw_nbytes']/rep['packed_nbytes']:.2f}x smaller), "
          f"avg W bits {rep['avg_w_bits']:.2f}")

    out_f, dt_f, _ = _timed_serve(eng_f, prompts, args.new_tokens)
    out_c, dt_c, _ = _timed_serve(eng_percall, prompts, args.new_tokens)
    out_p, dt_p, tps_p = _timed_serve(eng_packed, prompts, args.new_tokens)
    out_s, dt_s, tps_s = _timed_serve(eng_spec, prompts, args.new_tokens)
    st = eng_packed.last_stats
    st_s = eng_spec.last_stats

    # batch-invariance: each request == its own batch-1 greedy generation
    eng_1 = Engine(eng_packed.params, cfg_q, ServeConfig(max_len=128, batch_size=1))
    solo_ok = all(
        bool((out_p[i] == eng_1.generate(p[None, :], len(out_p[i]))[0]).all())
        for i, p in enumerate(prompts)
    )
    exact = all((out_p[i] == out_c[i]).all() for i in out_p)
    spec_exact = all(np.array_equal(out_p[i], out_s[i]) for i in out_p)
    agree = np.mean([float((out_f[i] == out_p[i]).mean()) for i in out_p])
    print(f"served {len(prompts)} ragged requests (lens {lens.tolist()}) on "
          f"{args.batch} slots, occupancy {st['occupancy']*100:.0f}%")
    print(f"packed == per-call quantized (token-for-token): {exact}")
    print(f"speculative == non-speculative packed (token-for-token): "
          f"{spec_exact}")
    print(f"ragged batch == batch-size-1 per request: {solo_ok}")
    print(f"float vs DSBP token agreement: {agree*100:.1f}%")
    print(f"serve wall: float {dt_f:.2f}s | quantize-per-call {dt_c:.2f}s | "
          f"pack-once {dt_p:.2f}s ({dt_c/dt_p:.2f}x vs per-call) | "
          f"spec {dt_s:.2f}s")
    print(f"speculation: k={args.spec_k} @ {args.spec_draft_bits}b draft, "
          f"{st_s['spec_rounds']} rounds vs {st['decode_steps']} pool steps, "
          f"mean accepted {st_s['mean_accepted']:.2f}/{args.spec_k + 1}, "
          f"decode-phase {tps_s:.0f} vs {tps_p:.0f} tok/s "
          f"({tps_s / tps_p:.2f}x)")
    for uid in list(out_p)[:2]:
        print(f"  req{uid} float : {out_f[uid][:12]}")
        print(f"  req{uid} packed: {out_p[uid][:12]}")
    if not exact:
        raise SystemExit("packed serving diverged from per-call DSBP serving")
    if not spec_exact:
        raise SystemExit("speculative serving diverged from the "
                         "non-speculative token stream")
    if not solo_ok:
        raise SystemExit("ragged batch diverged from batch-size-1 serving")

    # ---- paged KV: shared system prompt on a fixed KV HBM budget --------
    n_shared = 2 * args.batch
    sys_prompt = rng.integers(0, cfg.vocab_size, (24,))
    shared_reqs = [np.concatenate([sys_prompt,
                                   rng.integers(0, cfg.vocab_size, (4,))])
                   for _ in range(n_shared)]
    eng_dense = Engine(eng_packed.params, cfg_q, ServeConfig(
        max_len=128, batch_size=n_shared))
    out_d = eng_dense.serve(shared_reqs, max_new_tokens=args.new_tokens)
    # kv_blocks defaults to --batch dense slots' worth: HALF the lanes' KV
    eng_paged = Engine(eng_packed.params, cfg_q, ServeConfig(
        max_len=128, batch_size=args.batch, paged=True, kv_block_size=8,
        max_active=n_shared))
    out_pg = eng_paged.serve(shared_reqs, max_new_tokens=args.new_tokens)
    stp = eng_paged.last_stats
    paged_exact = all(np.array_equal(out_d[i], out_pg[i]) for i in out_d)
    print(f"paged KV, shared system prompt ({len(sys_prompt)} tokens x "
          f"{n_shared} requests on {args.batch} dense slots' KV budget):")
    print(f"  paged == dense packed (token-for-token): {paged_exact}")
    print(f"  {stp['max_concurrent']} concurrent lanes "
          f"(> {args.batch} dense slots), block pool peak "
          f"{stp['block_peak_used']}/{stp['kv_blocks'] - 1} "
          f"({stp['block_utilization']*100:.0f}%), "
          f"{stp['shared_blocks_peak']} shared blocks at peak, "
          f"{stp['prefix_hit_blocks']} prefix hits -> "
          f"{stp['bytes_saved_sharing']/1e6:.2f} MB KV never re-materialized")
    if not paged_exact:
        raise SystemExit("paged serving diverged from the dense engine")
    if not (stp["max_concurrent"] > args.batch
            and stp["shared_blocks_peak"] > 0):
        raise SystemExit("prefix sharing failed to over-subscribe the pool")

    # ---- packed KV cache: quantize at write, serve without dequant ------
    eng_kvq = Engine(eng_packed.params, cfg_q, ServeConfig(
        max_len=128, batch_size=args.batch, kv_quant="kv8"))
    eng_kvq_pg = Engine(eng_packed.params, cfg_q, ServeConfig(
        max_len=128, batch_size=args.batch, paged=True, kv_block_size=8,
        kv_quant="kv8"))
    out_k, dt_k, _ = _timed_serve(eng_kvq, prompts, args.new_tokens)
    stk = eng_kvq.last_stats
    out_kp = eng_kvq_pg.serve(prompts, max_new_tokens=args.new_tokens)
    stkp = eng_kvq_pg.last_stats
    kv_exact = all(np.array_equal(out_k[i], out_kp[i]) for i in out_k)
    kv_agree = np.mean([float((out_p[i] == out_k[i]).mean()) for i in out_p])
    kv_ratio = st["kv_bytes_per_token"] / stk["kv_bytes_per_token"]
    print(f"packed KV cache (kv8, DESIGN.md §14): "
          f"{st['kv_bytes_per_token']:.0f} -> "
          f"{stk['kv_bytes_per_token']:.0f} KV bytes/token "
          f"({kv_ratio:.2f}x smaller), packed dense={stk['kv_packed']} "
          f"paged={stkp['kv_packed']}")
    print(f"  dense-kv8 == paged-kv8 (token-for-token): {kv_exact}")
    print(f"  float-cache vs kv8-cache token agreement: {kv_agree*100:.1f}%")
    if not kv_exact:
        raise SystemExit("paged packed-KV serving diverged from dense")
    if not (stk["kv_packed"] and stkp["kv_packed"] and kv_ratio >= 3.0):
        raise SystemExit("packed KV cache saved fewer than 3x bytes/token")

    # ---- robustness: seeded faults on an over-subscribed paged pool -----
    mix = [Request(uid=f"r{i}",
                   tokens=rng.integers(0, cfg.vocab_size, (int(l),)),
                   max_new_tokens=args.new_tokens,
                   priority=1 if i % 3 == 0 else 0)
           for i, l in enumerate(lens)]
    mix.append(Request(uid="background",
                       tokens=rng.integers(0, cfg.vocab_size, (32,)),
                       max_new_tokens=2 * args.new_tokens, priority=0,
                       deadline_steps=3 * args.new_tokens))
    uids = [r.uid for r in mix]
    eng_rob = Engine(eng_packed.params, cfg_q, ServeConfig(
        max_len=128, batch_size=args.batch, paged=True, kv_block_size=8,
        kv_blocks=1 + 2 * len(mix), max_active=args.batch + 2,
        numeric_guard="quarantine-lane"))
    clean = eng_rob.serve([r for r in mix])
    plan = FA.FaultPlan.seeded(
        7, uids=uids, n_alloc=2, n_cow=2, n_nan=1, n_cancel=1,
        decode_calls=2 * args.new_tokens, alloc_calls=len(mix) * 2,
        steps=args.new_tokens, lanes=args.batch + 2)
    out_r = eng_rob.serve([r for r in mix], faults=plan)
    str_ = eng_rob.last_stats
    status = str_["request_status"]
    lost = [u for u in uids if u not in out_r or u not in status]
    survivors = [u for u in uids if status.get(u) in ("ok", "preempted")]
    exact_r = all(np.array_equal(out_r[u], clean[u]) for u in survivors)
    prefix_r = all(np.array_equal(out_r[u], clean[u][: len(out_r[u])])
                   for u in uids)
    FA.check_invariants(eng_rob._last_alloc, out=out_r, uids=uids)
    by_state: dict = {}
    for s in status.values():
        by_state[s] = by_state.get(s, 0) + 1
    print(f"robustness, {len(mix)} requests on "
          f"{eng_rob.kv_blocks - 1} blocks under seeded faults "
          f"(injected {dict(plan.injected)}):")
    print(f"  statuses {by_state}, lost {len(lost)}, "
          f"{str_['preemptions']} preemptions / {str_['resumed']} resumes, "
          f"{str_['quarantined']} quarantined, "
          f"{str_['invariant_checks']} invariant checks")
    print(f"  survivors bit-exact vs unfaulted: {exact_r}; every stream a "
          f"clean prefix: {prefix_r}")
    if lost:
        raise SystemExit(f"requests lost under the fault plan: {lost}")
    if not (exact_r and prefix_r):
        raise SystemExit("a faulted stream diverged from the unfaulted run")
    if str_["preemptions"] < 1 or str_["resumed"] < 1:
        raise SystemExit("the fault plan exercised no preempt-resume cycle")

    # ---- observability: the same fault mix, traced end to end ----------
    eng_obs = Engine(eng_packed.params, cfg_q, ServeConfig(
        max_len=128, batch_size=args.batch, paged=True, kv_block_size=8,
        kv_blocks=1 + 2 * len(mix), max_active=args.batch + 2,
        numeric_guard="quarantine-lane", observe=True))
    plan_t = FA.FaultPlan.seeded(
        7, uids=uids, n_alloc=2, n_cow=2, n_nan=1, n_cancel=1,
        decode_calls=2 * args.new_tokens, alloc_calls=len(mix) * 2,
        steps=args.new_tokens, lanes=args.batch + 2)
    # guarantee at least one guard trip so the quant-health pillar fires
    plan_t.nan_steps = dict(plan_t.nan_steps)
    plan_t.nan_steps[2] = "all"
    eng_obs.serve([r for r in mix], faults=plan_t)
    sto = eng_obs.last_stats
    spans_ok = eng_obs.obs.complete_spans(sto["request_status"])
    summ = eng_obs.obs.request_summary()
    print(f"observability, same mix traced (observe=True): "
          f"{len(eng_obs.obs.trace.events)} events, "
          f"{eng_obs.obs.trace.dropped} dropped, "
          f"{eng_obs.obs.health.total_trips} guard trips "
          f"({eng_obs.obs.health.unattributed_trips} unattributed)")
    for uid in sorted(summ, key=str)[:3]:
        s = summ[uid]
        ttft = "-" if s["ttft_s"] is None else f"{1e3 * s['ttft_s']:.1f}ms"
        print(f"  req {uid}: {s['status']} ttft {ttft} {s['tokens']} tok")
    print(f"  span tree complete + terminal statuses match: {spans_ok}")
    if not spans_ok:
        raise SystemExit("a traced request has an incomplete span tree")
    if eng_obs.obs.health.total_trips < 1:
        raise SystemExit("forced NaN injection produced no guard telemetry")
    if eng_obs.obs.trace.dropped:
        raise SystemExit("the trace recorder dropped events under faults")


if __name__ == "__main__":
    main()

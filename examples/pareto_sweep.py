"""(k, B_fix) hyperparameter exploration — the paper's Fig. 7 sweep,
rebuilt on the policy subsystem (DESIGN.md §9).

Where the old sweep quantized ONE synthetic matmul, this one prices every
candidate against a real model end to end:

  * modeled avg I/W widths + TOPS/W come from ONE calibration pass
    (``repro.policy.calibrate`` histograms price every candidate by pure
    arithmetic — no per-candidate model runs);
  * accuracy is measured through ``serve.Engine`` on the synthetic
    BoolQ/Winogrande eval (gold labels from the float model, decided items
    only), i.e. the same harness the autotuner optimizes against.

  PYTHONPATH=src python examples/pareto_sweep.py [--items 48] [--no-eval] \
      > pareto.csv
"""
import argparse
import sys

from repro.configs import smoke_config
from repro.core.dsbp import DSBPConfig
from repro.core.quantized import QuantizedMatmulConfig
from repro.eval import harness
from repro.policy import (
    DSBPPolicy,
    assignment_cost,
    calibrate,
    synthetic_calibration_batches,
)
from repro.serve.engine import Engine, ServeConfig

sys.path.insert(0, ".")  # benchmarks.common for the trained-like weights
from benchmarks.common import llama_like_model_params  # noqa: E402


def candidate(k, b_in, b_w):
    return QuantizedMatmulConfig(
        input_cfg=DSBPConfig(fmt="e4m3", side="input", k=k, b_fix=b_in),
        weight_cfg=DSBPConfig(fmt="e2m5", side="weight", k=k, b_fix=b_w,
                              scale_granularity="row"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--items", type=int, default=48)
    ap.add_argument("--no-eval", action="store_true",
                    help="modeled efficiency only (fast)")
    args = ap.parse_args()

    cfg = smoke_config(args.arch).replace(dtype="float32", remat=False)
    params = llama_like_model_params(cfg, 0)
    report = calibrate(params, cfg,
                       synthetic_calibration_batches(cfg, 2, 2, 32, seed=0))

    tasks, golds = [], []
    if not args.no_eval:
        tasks, golds = harness.decided_tasks(params, cfg, args.items)

    rows = []
    for k in (0.5, 1.0, 2.0):
        for b_in in (2, 3, 4, 6):
            for b_w in (3, 5, 7):
                c = candidate(k, b_in, b_w)
                cost = assignment_cost(report, {p: c for p in report.layers})
                accs = (float("nan"), float("nan"))
                if not args.no_eval:
                    pol = DSBPPolicy.uniform(c, report.layers.keys())
                    eng = Engine(params, cfg,
                                 ServeConfig(max_len=256, pack_preset=pol,
                                             quant_method="dsbp_ref"))
                    accs = tuple(harness.evaluate(eng, t, g)
                                 for t, g in zip(tasks, golds))
                rows.append((k, b_in, b_w, cost["avg_i"], cost["avg_w"],
                             cost["eff_tops_w"], accs[0], accs[1]))
                print(f"# {len(rows)} configs done", end="\r", file=sys.stderr)

    # Pareto frontier on (min task accuracy, modeled efficiency)
    def acc_of(r):
        return min(r[6], r[7]) if not args.no_eval else -r[3] * r[4]

    pareto = {i for i, r in enumerate(rows)
              if not any((acc_of(o) >= acc_of(r) and o[5] > r[5]) or
                         (acc_of(o) > acc_of(r) and o[5] >= r[5])
                         for o in rows)}

    print("k,b_fix_in,b_fix_w,avg_I,avg_W,eff_tops_w,acc_boolq,acc_wino,pareto")
    for i, r in enumerate(rows):
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.2f},{r[4]:.2f},{r[5]:.2f},"
              f"{r[6]:.3f},{r[7]:.3f},{int(i in pareto)}")
    print(f"# {len(pareto)} Pareto-optimal of {len(rows)} configs",
          file=sys.stderr)


if __name__ == "__main__":
    main()

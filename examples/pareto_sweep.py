"""(k, B_fix) hyperparameter exploration — the paper's Fig. 7 sweep as CSV.

Sweeps the DSBP knobs over Llama-like layer data and emits
(k, b_fix_in, b_fix_w, avg_I, avg_W, sqnr_db, tflops_per_w) rows, marking
the Pareto frontier.  This is the offline exploration loop the paper
describes for choosing Precise/Efficient configurations.

  PYTHONPATH=src python examples/pareto_sweep.py > pareto.csv
"""
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import energy as E
from repro.core import quantized as Q
from repro.core.dsbp import DSBPConfig


def llama_like(shape, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(shape).astype(np.float32)
    return x * rng.lognormal(0, 1.2, shape[-1]).astype(np.float32)


def main():
    x = jnp.asarray(llama_like((128, 2048), 0))
    w = jnp.asarray(np.random.default_rng(1).standard_normal((2048, 128))
                    .astype(np.float32) * 0.03)
    exact = np.asarray(x) @ np.asarray(w)

    rows = []
    for k in (0.0, 0.5, 1.0, 1.5, 2.0):
        for b_in in (3, 4, 5, 6, 7):
            for b_w in (3, 4, 5):
                cfg = Q.QuantizedMatmulConfig(
                    input_cfg=DSBPConfig(fmt="e4m3", side="input",
                                         mode="dsbp", k=k, b_fix=b_in),
                    weight_cfg=DSBPConfig(fmt="e2m5", side="weight", mode="dsbp",
                                          k=k, b_fix=b_w,
                                          scale_granularity="row"),
                )
                y = np.asarray(Q.dsbp_matmul_ref(x, w, cfg))
                st = jax.tree.map(float, Q.matmul_stats(x, w, cfg))
                err = np.abs(y - exact)
                sqnr = 10 * np.log10((exact**2).mean() / (err**2).mean())
                eff = E.efficiency_tops_per_w(st["avg_i_bits"],
                                              st["avg_w_bits"], "fp_dsbp")
                rows.append((k, b_in, b_w, st["avg_i_bits"], st["avg_w_bits"],
                             sqnr, eff))

    pareto = set()
    for i, r in enumerate(rows):
        if not any(o[5] >= r[5] and o[6] > r[6] or o[5] > r[5] and o[6] >= r[6]
                   for o in rows):
            pareto.add(i)

    print("k,b_fix_in,b_fix_w,avg_I,avg_W,sqnr_db,tflops_per_w,pareto")
    for i, r in enumerate(rows):
        print(f"{r[0]},{r[1]},{r[2]},{r[3]:.2f},{r[4]:.2f},{r[5]:.2f},"
              f"{r[6]:.1f},{int(i in pareto)}")
    print(f"# {len(pareto)} Pareto-optimal of {len(rows)} configs",
          file=sys.stderr)


if __name__ == "__main__":
    main()
